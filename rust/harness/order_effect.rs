//! Fig. 3 — the order effect (DESIGN.md E1).
//!
//! Trains WASGD+ with forced δ-label-blocked sample orders,
//! δ ∈ {1, 10, 100, 1000}, on the Fashion-MNIST and (optionally)
//! CIFAR-10 analogues, and emits accuracy/loss curves vs iteration.
//! Paper shape to reproduce: δ=1 ≻ δ=10 ≻ δ=100 ≻ δ=1000, with the gap
//! widening on the harder dataset.
//!
//! ```bash
//! cargo run --release --bin bench_order_effect -- [--dataset fashion]
//!     [--epochs 1.0] [--p 4] [--deltas 1,10,100,1000]
//! ```

use anyhow::Result;
use wasgd::config::{AlgoKind, ExperimentConfig};
use wasgd::harness::SharedEnv;
use wasgd::data::synth::DatasetKind;
use wasgd::harness::RESULTS_DIR;
use wasgd::metrics::write_csv;
use wasgd::util::Args;

fn main() -> Result<()> {
    let args = Args::parse_env()?;
    let dataset_s = args.str_flag("dataset", "fashion");
    let epochs = args.num_flag("epochs", 1.0f64)?;
    let p = args.num_flag("p", 4usize)?;
    let deltas_s = args.str_flag("deltas", "1,10,100,1000");
    args.finish()?;

    let dataset = DatasetKind::parse(&dataset_s)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset {dataset_s:?}"))?;
    let deltas: Vec<usize> = deltas_s
        .split(',')
        .filter(|s| !s.is_empty())
        .map(|s| s.parse())
        .collect::<Result<_, _>>()?;

    let env = SharedEnv::new(&ExperimentConfig::paper_preset(dataset))?;

    println!("Fig. 3 order effect — {} (p={p}, {epochs} epochs)", dataset.name());
    let mut logs = Vec::new();
    let mut summary = Vec::new();
    for &delta in &deltas {
        let mut cfg = ExperimentConfig::paper_preset(dataset);
        cfg.algo = AlgoKind::WasgdPlus;
        cfg.p = p;
        cfg.epochs = epochs;
        cfg.force_delta_order = Some(delta);
        cfg.eval_every = (cfg.tau / 2).max(16);
        cfg.eval_batches = 8;
        let mut out = env.run(&cfg)?;
        out.log.label = format!("delta={delta}");
        let last = out.log.records.last().unwrap().clone();
        println!(
            "δ={delta:<5} final train_loss {:>8.4}  train_err {:>6.3}  test_err {:>6.3}",
            last.train_loss, last.train_error, last.test_error
        );
        summary.push((delta, last.train_loss));
        logs.push(out.log);
    }

    let path = format!("{RESULTS_DIR}/fig3_order_effect_{}.csv", dataset.name());
    write_csv(&path, &logs)?;
    println!("wrote {path}");

    // Shape check (paper: smaller δ converges better).
    let first = summary.first().unwrap().1;
    let last = summary.last().unwrap().1;
    println!(
        "\nshape: δ={} loss {first:.4} vs δ={} loss {last:.4} → {}",
        summary.first().unwrap().0,
        summary.last().unwrap().0,
        if first <= last { "interleaved order wins (matches paper)" } else { "MISMATCH" }
    );
    Ok(())
}
