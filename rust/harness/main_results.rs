//! Figs. 8–11 — the main results (DESIGN.md E6–E9): all seven schemes on
//! one dataset analogue, across worker counts, plotting train/test
//! loss/error against *simulated cluster time*.
//!
//! Paper shapes to reproduce:
//! * WASGD+ dominates every baseline in time-to-loss at p ∈ {4, 8};
//! * SPSGD destabilises as p grows (non-convex parameter averaging);
//! * OMWU trails because full-dataset weight evaluation is charged;
//! * MMWU ≈ sequential SGD; EASGD sits between SPSGD and WASGD.
//!
//! ```bash
//! cargo run --release --bin bench_main -- --dataset mnist   # Fig. 11
//! cargo run --release --bin bench_main -- --dataset fashion # Fig. 10
//! cargo run --release --bin bench_main -- --dataset cifar10 --epochs 0.5   # Fig. 8
//! cargo run --release --bin bench_main -- --dataset cifar100 --epochs 0.5  # Fig. 9
//! ```
//!
//! Every dataset — including the CIFAR analogues, whose `cifar_cnn*`
//! variants run on the native conv path — works from a clean checkout
//! with no artifacts; `--backend pjrt` switches to lowered artifacts.

use anyhow::Result;
use wasgd::config::{AlgoKind, ExperimentConfig};
use wasgd::harness::SharedEnv;
use wasgd::data::synth::DatasetKind;
use wasgd::harness::RESULTS_DIR;
use wasgd::metrics::write_csv;
use wasgd::util::Args;

fn main() -> Result<()> {
    let args = Args::parse_env()?;
    let dataset_s = args.str_flag("dataset", "mnist");
    let epochs = args.num_flag("epochs", 1.0f64)?;
    let ps_s = args.opt_str("ps");
    args.finish()?;

    let dataset = DatasetKind::parse(&dataset_s)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset {dataset_s:?}"))?;
    // Paper: GPUs p ∈ {2,4,8} for CIFAR, CPUs p ∈ {4,8,16} for (F)MNIST.
    let default_ps = match dataset {
        DatasetKind::Cifar10Like | DatasetKind::Cifar100Like => "2,4,8",
        _ => "4,8,16",
    };
    let ps: Vec<usize> = ps_s
        .unwrap_or_else(|| default_ps.to_string())
        .split(',')
        .filter(|s| !s.is_empty())
        .map(|s| s.parse())
        .collect::<Result<_, _>>()?;

    let fig = match dataset {
        DatasetKind::Cifar10Like => "fig8",
        DatasetKind::Cifar100Like => "fig9",
        DatasetKind::FashionLike => "fig10",
        _ => "fig11",
    };
    println!(
        "{} main results — {} ({} epochs, p ∈ {ps:?})",
        fig,
        dataset.name(),
        epochs
    );

    let env = SharedEnv::new(&ExperimentConfig::paper_preset(dataset))?;
    let mut logs = Vec::new();
    for &p in &ps {
        println!("\np = {p}");
        println!(
            "{:<12} {:>11} {:>10} {:>10} {:>10} {:>11}",
            "algo", "train_loss", "train_err", "test_loss", "test_err", "sim_time_s"
        );
        let mut rows: Vec<(String, f64, f64)> = Vec::new();
        for algo in AlgoKind::ALL {
            let mut cfg = ExperimentConfig::paper_preset(dataset);
            cfg.algo = algo;
            cfg.p = p;
            cfg.backups = 1;
            cfg.epochs = epochs;
            cfg.eval_every = (cfg.tau / 2).max(32);
            cfg.eval_batches = 6;
            let mut out = env.run(&cfg)?;
            out.log.label = format!("{} p={p}", algo.name());
            let r = out.log.records.last().unwrap().clone();
            println!(
                "{:<12} {:>11.4} {:>10.3} {:>10.4} {:>10.3} {:>11.2}",
                algo.name(),
                r.train_loss,
                r.train_error,
                r.test_loss,
                r.test_error,
                r.sim_time_s
            );
            rows.push((algo.name().to_string(), r.train_loss, r.sim_time_s));
            logs.push(out.log);
        }
        // Shape check: WASGD+ should have the best (or near-best) loss.
        let plus = rows.iter().find(|(n, _, _)| n == "wasgd+").unwrap().1;
        let best = rows
            .iter()
            .map(|&(_, l, _)| l)
            .fold(f64::INFINITY, f64::min);
        println!(
            "→ wasgd+ loss {plus:.4} vs best {best:.4} {}",
            if plus <= best * 1.10 { "(wins/ties — matches paper)" } else { "(MISMATCH)" }
        );
    }

    let path = format!("{RESULTS_DIR}/{fig}_main_{}.csv", dataset.name());
    write_csv(&path, &logs)?;
    println!("\nwrote {path}");
    Ok(())
}
