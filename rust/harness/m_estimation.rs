//! Fig. 6 — weight-estimation accuracy vs sample count m (DESIGN.md E4).
//!
//! At every communication boundary the coordinator estimates the
//! Boltzmann weights from the m recorded batch losses (Eq. 26) and —
//! with the probe enabled — also computes the exact weights from a
//! full-dataset evaluation (Eq. 20). The per-boundary L1 gap is the
//! paper's Eq. (27) error (∈ [0, 2]). Paper shape: m ∈ {1, 10} noisy and
//! unstable, m ∈ {100, 1000} accurate; m = 100 is the efficiency pick.
//!
//! ```bash
//! cargo run --release --bin bench_m_estimation -- [--dataset mnist]
//!     [--epochs 2.0] [--p 4] [--ms 1,10,100,1000]
//! ```

use anyhow::Result;
use wasgd::config::{AlgoKind, ExperimentConfig};
use wasgd::harness::SharedEnv;
use wasgd::data::synth::DatasetKind;
use wasgd::harness::RESULTS_DIR;
use wasgd::util::Args;

fn main() -> Result<()> {
    let args = Args::parse_env()?;
    let dataset_s = args.str_flag("dataset", "mnist");
    let epochs = args.num_flag("epochs", 2.0f64)?;
    let p = args.num_flag("p", 4usize)?;
    let ms_s = args.str_flag("ms", "1,10,100,1000");
    args.finish()?;

    let dataset = DatasetKind::parse(&dataset_s)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset {dataset_s:?}"))?;
    let ms: Vec<usize> = ms_s
        .split(',')
        .filter(|s| !s.is_empty())
        .map(|s| s.parse())
        .collect::<Result<_, _>>()?;

    let env = SharedEnv::new(&ExperimentConfig::paper_preset(dataset))?;

    println!("Fig. 6 estimation accuracy — {} (p={p}, {epochs} epochs)", dataset.name());
    println!("{:>6}  {:>10}  {:>10}  {:>10}  {:>10}", "m", "mean err", "max err", "min err", "boundaries");

    let mut all_rows: Vec<(String, Vec<(u64, f32)>)> = Vec::new();
    let mut means = Vec::new();
    for &m in &ms {
        let mut cfg = ExperimentConfig::paper_preset(dataset);
        cfg.algo = AlgoKind::WasgdPlus;
        cfg.p = p;
        cfg.epochs = epochs;
        cfg.m = m;
        cfg.c = if m >= 4 { 4 } else { 1 };
        cfg.track_estimation_error = true;
        cfg.eval_every = usize::MAX / 2; // only the probe matters here
        let out = env.run(&cfg)?;
        let errs = &out.estimation_errors;
        let mean = errs.iter().map(|&(_, e)| e as f64).sum::<f64>() / errs.len().max(1) as f64;
        let max = errs.iter().map(|&(_, e)| e).fold(0.0f32, f32::max);
        let min = errs.iter().map(|&(_, e)| e).fold(2.0f32, f32::min);
        println!("{m:>6}  {mean:>10.5}  {max:>10.5}  {min:>10.5}  {:>10}", errs.len());
        means.push((m, mean));
        all_rows.push((format!("m={m}"), errs.clone()));
    }

    // CSV: iteration,error per m-series.
    let path = format!("{RESULTS_DIR}/fig6_m_estimation_{}.csv", dataset.name());
    {
        use std::io::Write as _;
        std::fs::create_dir_all(RESULTS_DIR)?;
        let mut f = std::fs::File::create(&path)?;
        writeln!(f, "series,iteration,eq27_error")?;
        for (label, errs) in &all_rows {
            for &(it, e) in errs {
                writeln!(f, "{label},{it},{e:.6}")?;
            }
        }
    }
    println!("wrote {path}");

    // Shape: error should shrink with m.
    let first = means.first().unwrap();
    let biggest = means.last().unwrap();
    println!(
        "\nshape: m={} mean err {:.4} vs m={} mean err {:.4} → {}",
        first.0,
        first.1,
        biggest.0,
        biggest.1,
        if biggest.1 <= first.1 { "larger m estimates better (matches paper)" } else { "MISMATCH" }
    );
    Ok(())
}
