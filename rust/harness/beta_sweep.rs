//! Fig. 5 — β exploration (DESIGN.md E3).
//!
//! β determines how much of the aggregation result each worker accepts
//! (Eq. 10). Baseline is full acceptance (β = 1); candidates sweep
//! β ∈ {0.1 … 0.9}. Paper shape: an optimum strictly below 1 (0.9 for
//! MNIST/CIFAR-10, 0.8 for CIFAR-100, 0.7 for Fashion) and degradation
//! toward the sequential case as β → 0.
//!
//! ```bash
//! cargo run --release --bin bench_beta_sweep -- [--dataset mnist]
//!     [--epochs 1.0] [--p 4] [--betas 0.1,...,1.0]
//! ```

use anyhow::Result;
use wasgd::config::{AlgoKind, ExperimentConfig};
use wasgd::data::synth::DatasetKind;
use wasgd::harness::{eq47_point, print_sweep, write_sweep_csv, SharedEnv, RESULTS_DIR, SWEEP_SEEDS};
use wasgd::util::Args;

fn main() -> Result<()> {
    let args = Args::parse_env()?;
    let dataset_s = args.str_flag("dataset", "mnist");
    let epochs = args.num_flag("epochs", 1.0f64)?;
    let p = args.num_flag("p", 4usize)?;
    let betas_s = args.str_flag("betas", "0.1,0.2,0.3,0.4,0.5,0.6,0.7,0.8,0.9");
    let seeds_n = args.num_flag("seeds", 5usize)?;
    args.finish()?;

    let dataset = DatasetKind::parse(&dataset_s)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset {dataset_s:?}"))?;
    let betas: Vec<f32> = betas_s
        .split(',')
        .filter(|s| !s.is_empty())
        .map(|s| s.parse())
        .collect::<Result<_, _>>()?;
    let seeds = &SWEEP_SEEDS[..seeds_n.min(SWEEP_SEEDS.len())];

    let mut base = ExperimentConfig::paper_preset(dataset);
    base.algo = AlgoKind::WasgdPlus;
    base.p = p;
    base.epochs = epochs;
    base.eval_every = (base.tau / 2).max(32);
    base.eval_batches = 6;
    let env = SharedEnv::new(&base)?;

    println!(
        "Fig. 5 β-sweep — {} (p={p}, {epochs} epochs, {} seeds); baseline β=1",
        dataset.name(),
        seeds.len()
    );

    let mut b1 = base.clone();
    b1.beta = 1.0;
    let baseline: Vec<_> = env.run_seeds(&b1, seeds)?.into_iter().map(|o| o.log).collect();

    let mut loss_rows = Vec::new();
    let mut err_rows = Vec::new();
    for &beta in &betas {
        let mut cfg = base.clone();
        cfg.beta = beta;
        let cand: Vec<_> = env.run_seeds(&cfg, seeds)?.into_iter().map(|o| o.log).collect();
        let (dl, el) = eq47_point(&baseline, &cand, |r| r.train_loss);
        let (de, ee) = eq47_point(&baseline, &cand, |r| r.train_error);
        loss_rows.push((format!("{beta}"), dl, el));
        err_rows.push((format!("{beta}"), de, ee));
    }

    print_sweep("Δ train loss vs β=1 baseline (positive = partial acceptance better)", "β", &loss_rows);
    print_sweep("Δ train error vs β=1 baseline", "β", &err_rows);

    write_sweep_csv(
        &format!("{RESULTS_DIR}/fig5_beta_sweep_{}_loss.csv", dataset.name()),
        "beta,delta_loss,err",
        &loss_rows,
    )?;
    write_sweep_csv(
        &format!("{RESULTS_DIR}/fig5_beta_sweep_{}_error.csv", dataset.name()),
        "beta,delta_error,err",
        &err_rows,
    )?;

    let best = loss_rows
        .iter()
        .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .unwrap();
    println!(
        "\noptimal β = {} (Δloss {:+.5}); paper: β* < 1, degrading as β→0",
        best.0, best.1
    );
    Ok(())
}
