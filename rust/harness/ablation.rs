//! Ablation study of WASGD+'s design choices (DESIGN.md §5 "ablation
//! benches"): each row removes ONE mechanism from the full method and
//! reports the Eq. 47 delta against full WASGD+ (negative = the removal
//! hurt, i.e. the mechanism earns its place).
//!
//! | ablation | what changes |
//! |---|---|
//! | -order-search  | fresh uniform shuffles every epoch (no Judge/OrderGen) |
//! | -boltzmann     | equal weights (ã = 0) |
//! | -negotiation   | full acceptance (β = 1) |
//! | -estimation    | m = 1 (single-batch loss energy) |
//! | inverse-weights| WASGD's 1/h family instead of e^(−ã·h′) |
//!
//! ```bash
//! cargo run --release --bin bench_ablation -- [--dataset mnist] [--epochs 1] [--p 4]
//! ```

use anyhow::Result;
use wasgd::config::{AlgoKind, ExperimentConfig};
use wasgd::data::synth::DatasetKind;
use wasgd::harness::{eq47_point, write_sweep_csv, SharedEnv, RESULTS_DIR, SWEEP_SEEDS};
use wasgd::util::Args;

fn main() -> Result<()> {
    let args = Args::parse_env()?;
    let dataset_s = args.str_flag("dataset", "mnist");
    let epochs = args.num_flag("epochs", 1.0f64)?;
    let p = args.num_flag("p", 4usize)?;
    let seeds_n = args.num_flag("seeds", 5usize)?;
    args.finish()?;

    let dataset = DatasetKind::parse(&dataset_s)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset {dataset_s:?}"))?;
    let seeds = &SWEEP_SEEDS[..seeds_n.min(SWEEP_SEEDS.len())];

    let mut full = ExperimentConfig::paper_preset(dataset);
    full.algo = AlgoKind::WasgdPlus;
    full.p = p;
    full.epochs = epochs;
    full.eval_every = (full.tau / 2).max(32);
    full.eval_batches = 6;

    let env = SharedEnv::new(&full)?;
    println!(
        "WASGD+ ablations — {} (p={p}, {epochs} epochs, {} seeds); Δ<0 ⇒ removing the mechanism hurts",
        dataset.name(),
        seeds.len()
    );

    let baseline: Vec<_> = env.run_seeds(&full, seeds)?.into_iter().map(|o| o.log).collect();

    let ablations: Vec<(&str, Box<dyn Fn(&mut ExperimentConfig)>)> = vec![
        ("-order-search", Box::new(|c: &mut ExperimentConfig| {
            // Forced δ=1 orders disable the Judge/OrderGen machinery while
            // keeping the label mix maximally interleaved.
            c.force_delta_order = Some(1);
        })),
        ("-boltzmann (ã=0)", Box::new(|c| c.a_tilde = 0.0)),
        ("-negotiation (β=1)", Box::new(|c| c.beta = 1.0)),
        ("-estimation (m=1)", Box::new(|c| {
            c.m = 1;
            c.c = 1;
        })),
        ("inverse-weights (WASGD)", Box::new(|c| c.algo = AlgoKind::Wasgd)),
    ];

    let mut rows = Vec::new();
    println!("\n{:<26} {:>14} {:>12}", "ablation", "Δ train loss", "± err");
    for (name, apply) in &ablations {
        let mut cfg = full.clone();
        apply(&mut cfg);
        let cand: Vec<_> = env.run_seeds(&cfg, seeds)?.into_iter().map(|o| o.log).collect();
        // Candidate-minus-baseline orientation: negative = ablation worse.
        let (d, e) = eq47_point(&cand, &baseline, |r| r.train_loss);
        println!("{name:<26} {:>14.6} {e:>12.6}", -d);
        rows.push((name.to_string(), -d, e));
    }

    write_sweep_csv(
        &format!("{RESULTS_DIR}/ablation_{}.csv", dataset.name()),
        "ablation,delta_loss_vs_full,err",
        &rows,
    )?;
    println!("\nwrote {RESULTS_DIR}/ablation_{}.csv", dataset.name());
    Ok(())
}
