"""AOT lowering: jax → HLO *text* artifacts consumed by the rust runtime.

Run once at build time (``make artifacts``); python never appears on the
training path. For every model variant we emit::

    artifacts/<variant>/train_step.hlo.txt
    artifacts/<variant>/eval_step.hlo.txt
    artifacts/<variant>/aggregate_p{2,4,8,16}.hlo.txt
    artifacts/<variant>/manifest.json

Interchange format is HLO **text**, not ``lowered.compile().serialize()``:
jax ≥ 0.5 emits HloModuleProto with 64-bit instruction ids which the
``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``).
The text parser reassigns ids and round-trips cleanly — see
/opt/xla-example/README.md.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M

DEFAULT_VARIANTS = [
    "tiny_mlp",
    "mnist_mlp",
    "fashion_mlp",
    "mnist_cnn",
    "cifar_cnn10",
    "cifar_cnn100",
]
WORKER_COUNTS = [2, 4, 8, 16]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True so the
    rust side unwraps one tuple regardless of arity)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(spec: M.ModelSpec, out_dir: str, worker_counts=None) -> dict:
    """Lower all artifacts for one variant; returns its manifest dict."""
    worker_counts = worker_counts or WORKER_COUNTS
    os.makedirs(out_dir, exist_ok=True)
    d = M.param_count(spec)
    xdim = int(np.prod(spec.input_shape))

    flat, x, y, lr = M.example_args(spec)
    train = jax.jit(M.make_train_step(spec)).lower(flat, x, y, lr)
    with open(os.path.join(out_dir, "train_step.hlo.txt"), "w") as f:
        f.write(to_hlo_text(train))

    evl = jax.jit(M.make_eval_step(spec)).lower(flat, x, y)
    with open(os.path.join(out_dir, "eval_step.hlo.txt"), "w") as f:
        f.write(to_hlo_text(evl))

    s1 = jax.ShapeDtypeStruct((1,), np.float32)
    for p in worker_counts:
        stacked = jax.ShapeDtypeStruct((p, d), np.float32)
        h = jax.ShapeDtypeStruct((p,), np.float32)
        agg = jax.jit(M.make_aggregate(p)).lower(stacked, h, s1, s1)
        with open(os.path.join(out_dir, f"aggregate_p{p}.hlo.txt"), "w") as f:
            f.write(to_hlo_text(agg))

    manifest = {
        "name": spec.name,
        "param_count": d,
        "batch": spec.batch,
        "input_dim": xdim,
        "input_shape": list(spec.input_shape),
        "num_classes": spec.num_classes,
        "worker_counts": worker_counts,
        # Flat-ABI layout so the rust side can He-initialise without python.
        "param_layout": [
            {"name": n, "shape": list(s)} for n, s in M.param_shapes(spec)
        ],
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts root")
    ap.add_argument(
        "--variants",
        default=",".join(DEFAULT_VARIANTS),
        help="comma-separated variant names (see compile.model.VARIANTS)",
    )
    ap.add_argument(
        "--workers",
        default=",".join(str(p) for p in WORKER_COUNTS),
        help="comma-separated worker counts to lower aggregate kernels for",
    )
    args = ap.parse_args()

    worker_counts = [int(p) for p in args.workers.split(",") if p]
    names = [v for v in args.variants.split(",") if v]
    top = {"variants": []}
    for name in names:
        spec = M.VARIANTS[name]
        mf = lower_variant(spec, os.path.join(args.out, name), worker_counts)
        top["variants"].append(name)
        print(f"lowered {name}: D={mf['param_count']} B={mf['batch']}")
    with open(os.path.join(args.out, "index.json"), "w") as f:
        json.dump(top, f, indent=1)
    print(f"artifacts written to {args.out}")


if __name__ == "__main__":
    main()
