"""L1 Pallas kernel: fused softmax cross-entropy (loss + logits-gradient).

The classifier head is the second hot spot of the training step: for the
CIFAR-100-like variant the logits are [B, 100] and the naive jnp lowering
materialises softmax, log-softmax and the gradient as separate HLO
fusions. This kernel computes, in one VMEM-resident pass per batch tile,

    loss_i    = -log softmax(logits_i)[y_i]
    dlogits_i = softmax(logits_i) - onehot_i

which is exactly the residual the backward pass needs — so the VJP is a
free lookup, not a recomputation (paper §3.3 makes the same observation:
the loss energy needed for the aggregation weights falls out of the
forward pass at no extra cost; we return the per-example losses for that
purpose).

Labels enter as a dense one-hot [B, C] f32 matrix. Pallas interpret mode
handles integer gathers fine, but one-hot keeps the kernel purely
vector-ALU shaped (TPU VPU-friendly: no cross-lane gather needed).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Batch tile: 128 rows per grid step keeps the (logits, onehot, dlogits)
# triple at 3·128·C·4 bytes — ≤ 1.5 MiB even at C=1024 — far under VMEM.
DEFAULT_BB = 128


def _xent_kernel(logits_ref, onehot_ref, loss_ref, dlogits_ref):
    logits = logits_ref[...]
    onehot = onehot_ref[...]
    m = jnp.max(logits, axis=-1, keepdims=True)
    z = logits - m
    ez = jnp.exp(z)
    denom = jnp.sum(ez, axis=-1, keepdims=True)
    # -Σ onehot · logsoftmax  (one-hot ⇒ picks the label column)
    loss_ref[...] = -jnp.sum(onehot * (z - jnp.log(denom)), axis=-1)
    dlogits_ref[...] = ez / denom - onehot


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("bb",))
def _xent_pallas(logits: jnp.ndarray, onehot: jnp.ndarray, bb: int):
    b, c = logits.shape
    bb = min(bb, _ceil_to(b, 8))
    bp = _ceil_to(b, bb)
    if bp != b:
        logits = jnp.pad(logits, ((0, bp - b), (0, 0)))
        # Pad rows get onehot=0 ⇒ loss 0; dlogits of pad rows are sliced off.
        onehot = jnp.pad(onehot, ((0, bp - b), (0, 0)))

    loss, dlogits = pl.pallas_call(
        _xent_kernel,
        grid=(bp // bb,),
        in_specs=[
            pl.BlockSpec((bb, c), lambda i: (i, 0)),
            pl.BlockSpec((bb, c), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bb,), lambda i: (i,)),
            pl.BlockSpec((bb, c), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp,), jnp.float32),
            jax.ShapeDtypeStruct((bp, c), jnp.float32),
        ],
        interpret=True,
    )(logits, onehot)
    return loss[:b], dlogits[:b]


@jax.custom_vjp
def softmax_xent(logits: jnp.ndarray, onehot: jnp.ndarray):
    """Per-example cross-entropy loss [B]; differentiable w.r.t. logits."""
    loss, _ = _xent_pallas(logits, onehot, DEFAULT_BB)
    return loss


def _xent_fwd(logits, onehot):
    loss, dlogits = _xent_pallas(logits, onehot, DEFAULT_BB)
    return loss, dlogits


def _xent_bwd(dlogits, g):
    # g is the cotangent of the per-example loss vector [B].
    return g[:, None] * dlogits, None


softmax_xent.defvjp(_xent_fwd, _xent_bwd)


def softmax_xent_with_grad(logits, onehot):
    """Non-differentiable entry returning (loss [B], dlogits [B, C])."""
    return _xent_pallas(logits, onehot, DEFAULT_BB)
