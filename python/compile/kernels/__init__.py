"""L1 Pallas kernels for the WASGD+ stack.

- :mod:`.matmul` — MXU-tiled matmul (fwd + custom VJP), the model's GEMM.
- :mod:`.softmax_xent` — fused cross-entropy loss + logits-grad.
- :mod:`.aggregate` — the paper's Boltzmann weighted-aggregation update.
- :mod:`.ref` — pure-jnp oracles used by the pytest suite.

All kernels run under ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); BlockSpecs are still written for the TPU memory system —
see DESIGN.md §Hardware-Adaptation.
"""

from .matmul import matmul, matmul_with_blocks, vmem_bytes as matmul_vmem_bytes
from .softmax_xent import softmax_xent, softmax_xent_with_grad
from .aggregate import (
    aggregate,
    aggregate_with_blocks,
    boltzmann_weights,
    vmem_bytes as aggregate_vmem_bytes,
)

__all__ = [
    "matmul",
    "matmul_with_blocks",
    "matmul_vmem_bytes",
    "softmax_xent",
    "softmax_xent_with_grad",
    "aggregate",
    "aggregate_with_blocks",
    "boltzmann_weights",
    "aggregate_vmem_bytes",
]
