"""Generate the native-backend parity fixture
(rust/tests/fixtures/native_parity.json).

Records one `train_step` each of the tiny-MLP and tiny-CNN variants
(the CNN section pins the native conv/maxpool path: 3×3 SAME convs +
2×2 max-pools through `lax`/Pallas) and one `aggregate`, computed by
the build-time Python pipeline (the L1/L2 kernels that the PJRT
artifacts are lowered from), so the rust `NativeEngine` can be pinned
against them at ≤1e-5 with **no Python at test time** — the JSON is
committed.

Run from the repo root:

    PYTHONPATH=python python -m compile.kernels.gen_fixture

Inputs are drawn from a fixed numpy seed; the fixture embeds them, so the
rust side never needs to reproduce numpy's RNG.
"""

from __future__ import annotations

import json
import pathlib

import numpy as np

from .. import model
from . import ref


OUT_PATH = pathlib.Path(__file__).resolve().parents[3] / "rust" / "tests" / "fixtures" / "native_parity.json"


def _f(arr) -> list:
    """Flatten to a plain list of Python floats (full repr precision)."""
    return [float(v) for v in np.asarray(arr, np.float32).reshape(-1)]


def _train_section(variant: str, rng, seed: int, lr: np.float32) -> dict:
    """One recorded train_step of `variant` with embedded inputs."""
    spec = model.VARIANTS[variant]
    xdim = int(np.prod(spec.input_shape))
    params = model.init_params(spec, seed=seed)
    x = rng.normal(0.0, 1.0, size=(spec.batch, xdim)).astype(np.float32)
    y = rng.integers(0, spec.num_classes, size=(spec.batch,)).astype(np.int32)
    train_step = model.make_train_step(spec)
    new_params, mean_loss, per_example = train_step(params, x, y, np.array([lr]))
    return {
        "variant": variant,
        "params": _f(params),
        "x": _f(x),
        "y": [int(v) for v in y],
        "new_params": _f(new_params),
        "loss": float(mean_loss),
        "per_example": _f(per_example),
    }


def main() -> None:
    spec = model.VARIANTS["tiny_mlp"]
    rng = np.random.default_rng(20260729)
    lr = np.float32(0.05)

    train = _train_section("tiny_mlp", rng, seed=7, lr=lr)

    p = 3
    d = model.param_count(spec)
    stacked = rng.normal(0.0, 0.5, size=(p, d)).astype(np.float32)
    h = rng.uniform(0.05, 2.0, size=(p,)).astype(np.float32)
    a_tilde, beta = np.float32(1.3), np.float32(0.7)
    agg_out = ref.aggregate_ref(stacked, h, a_tilde, beta)
    theta = ref.boltzmann_weights_ref(h, a_tilde)

    # A second RNG stream so adding the conv section does not disturb the
    # MLP/aggregate draws (the committed MLP numbers stay comparable).
    conv_rng = np.random.default_rng(20260730)
    conv_train = _train_section("tiny_cnn", conv_rng, seed=11, lr=lr)

    fixture = {
        "variant": spec.name,
        "lr": float(lr),
        "train": train,
        "conv_train": conv_train,
        "aggregate": {
            "p": p,
            "stacked": _f(stacked),
            "h": _f(h),
            "a_tilde": float(a_tilde),
            "beta": float(beta),
            "theta": _f(theta),
            "out": _f(agg_out),
        },
    }
    OUT_PATH.parent.mkdir(parents=True, exist_ok=True)
    OUT_PATH.write_text(json.dumps(fixture) + "\n")
    print(f"wrote {OUT_PATH} ({OUT_PATH.stat().st_size} bytes)")


if __name__ == "__main__":
    main()
