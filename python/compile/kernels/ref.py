"""Pure-jnp correctness oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here written
with nothing but `jax.numpy` ops. The pytest suite asserts
`assert_allclose(kernel(...), ref(...))` over a hypothesis-driven sweep of
shapes and dtypes — this is the core L1 correctness signal.
"""

from __future__ import annotations

import jax.numpy as jnp


def matmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Plain dense matmul oracle: ``a @ b`` in float32 accumulation."""
    return jnp.matmul(a.astype(jnp.float32), b.astype(jnp.float32))


def softmax_xent_ref(logits: jnp.ndarray, onehot: jnp.ndarray):
    """Fused softmax cross-entropy oracle.

    Returns ``(per_example_loss [B], dlogits [B, C])`` where
    ``loss_i = -log softmax(logits_i)[label_i]`` and
    ``dlogits = softmax(logits) - onehot`` (the gradient of the *sum* of
    per-example losses w.r.t. the logits).
    """
    logits = logits.astype(jnp.float32)
    m = jnp.max(logits, axis=-1, keepdims=True)
    z = logits - m
    ez = jnp.exp(z)
    denom = jnp.sum(ez, axis=-1, keepdims=True)
    log_softmax = z - jnp.log(denom)
    loss = -jnp.sum(onehot * log_softmax, axis=-1)
    dlogits = ez / denom - onehot
    return loss, dlogits


def boltzmann_weights_ref(h: jnp.ndarray, a_tilde) -> jnp.ndarray:
    """The paper's Eq. (13): θ = softmax(-ã · h / Σh).

    ``h`` holds the per-worker loss energies (non-negative). The energies
    are normalised by their sum before the Boltzmann exponent so the
    temperature ã is scale-free (paper §3.2).
    """
    h = h.astype(jnp.float32)
    hp = h / jnp.sum(h)
    e = jnp.exp(-a_tilde * hp)
    return e / jnp.sum(e)


def aggregate_ref(stacked: jnp.ndarray, h: jnp.ndarray, a_tilde, beta):
    """The paper's Eq. (10)+(13) in one shot for all p workers.

    ``stacked`` is [p, D] (one row per worker), ``h`` is [p].
    Returns [p, D] where row i = (1-β)·xᵢ + β·Σⱼ θⱼ xⱼ.
    """
    theta = boltzmann_weights_ref(h, a_tilde)  # [p]
    agg = jnp.einsum("p,pd->d", theta, stacked.astype(jnp.float32))  # [D]
    return (1.0 - beta) * stacked.astype(jnp.float32) + beta * agg[None, :]
