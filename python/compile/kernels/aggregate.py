"""L1 Pallas kernel: the paper's weighted aggregating update (Eq. 10+13).

This is *the* contribution kernel. At every communication point all p
workers hold parameters xⁱ ∈ R^D and loss energies hⁱ; the update is

    h'ⁱ = hⁱ / Σⱼ hⱼ                       (scale-free normalisation)
    θⁱ  = exp(-ã·h'ⁱ) / Σₖ exp(-ã·h'ᵏ)     (Boltzmann weights, Eq. 13)
    xⁱ ← (1-β)·xⁱ + β·Σⱼ θⱼ·xʲ             (β-negotiation, Eq. 10)

Shape view: stacked X is [p, D] with p ≤ 16 and D up to millions. The
kernel tiles along D only; each grid step loads the full [p, bd] column
panel into VMEM (p·bd·4 bytes — 512 KiB at p=16, bd=8192), computes the
θ-weighted column sum with a [1, p]×[p, bd] matmul on the MXU, and writes
the β-mixed panel back. θ itself is O(p) scalar work, computed once in
jnp and passed in as a tiny operand (prologue — the SMEM-style scalar
path on real TPU).

The kernel is the TPU re-think of what the paper did with a parameter
all-reduce on the K80 cluster: the reduction over workers becomes a tiny
matvec per VMEM panel instead of a tree reduce over device buffers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Column-panel width. p·bd·4B + bd·4B ≈ 0.5 MiB (p=16, bd=8192): small
# enough to double-buffer, large enough that the per-step θ·X matvec
# saturates the VPU/MXU.
DEFAULT_BD = 8192


def _agg_kernel(theta_ref, beta_ref, x_ref, o_ref):
    theta = theta_ref[...]           # [1, p]
    beta = beta_ref[0, 0]            # scalar
    x = x_ref[...]                   # [p, bd]
    agg = jnp.dot(theta, x, preferred_element_type=jnp.float32)  # [1, bd]
    o_ref[...] = (1.0 - beta) * x + beta * agg


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def boltzmann_weights(h: jnp.ndarray, a_tilde) -> jnp.ndarray:
    """Eq. (13) — numerically-stable softmax of −ã·h/Σh."""
    h = h.astype(jnp.float32)
    hp = h / jnp.sum(h)
    z = -a_tilde * hp
    z = z - jnp.max(z)
    e = jnp.exp(z)
    return e / jnp.sum(e)


@functools.partial(jax.jit, static_argnames=("bd",))
def _aggregate_pallas(stacked, h, a_tilde, beta, bd: int):
    p, d = stacked.shape
    theta = boltzmann_weights(h, a_tilde).reshape(1, p)
    beta_arr = jnp.asarray(beta, jnp.float32).reshape(1, 1)

    bd = min(bd, _ceil_to(d, 8))
    dp = _ceil_to(d, bd)
    x = jnp.pad(stacked, ((0, 0), (0, dp - d))) if dp != d else stacked

    out = pl.pallas_call(
        _agg_kernel,
        grid=(dp // bd,),
        in_specs=[
            pl.BlockSpec((1, p), lambda i: (0, 0)),     # θ: replicated
            pl.BlockSpec((1, 1), lambda i: (0, 0)),     # β: replicated
            pl.BlockSpec((p, bd), lambda i: (0, i)),    # X column panel
        ],
        out_specs=pl.BlockSpec((p, bd), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((p, dp), jnp.float32),
        interpret=True,
    )(theta, beta_arr, x.astype(jnp.float32))
    return out[:, :d]


def aggregate(stacked: jnp.ndarray, h: jnp.ndarray, a_tilde, beta):
    """Weighted-aggregating update for all workers at once → [p, D]."""
    return _aggregate_pallas(stacked, h, a_tilde, beta, DEFAULT_BD)


def aggregate_with_blocks(stacked, h, a_tilde, beta, bd=DEFAULT_BD):
    """Perf-sweep entry exposing the panel width."""
    return _aggregate_pallas(stacked, h, a_tilde, beta, bd)


def vmem_bytes(p: int, bd: int = DEFAULT_BD, double_buffered: bool = True) -> int:
    """VMEM footprint of one grid step (DESIGN.md §Perf)."""
    mult = 2 if double_buffered else 1
    return (p * bd * 4) * 2 * mult + p * 4 + 4
