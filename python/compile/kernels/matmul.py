"""L1 Pallas kernel: tiled matmul, the MXU-shaped workhorse of the model.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper trained on
K80 GPUs where the hot spot is cuBLAS GEMM. On TPU the equivalent is a
systolic-array (MXU) matmul fed from VMEM. We express the HBM↔VMEM
schedule with a (M/bm, N/bn, K/bk) grid and BlockSpecs; the innermost K
axis accumulates into the output block, which Pallas keeps resident in
VMEM across the K steps (`dimension_semantics`: K is "arbitrary", M/N are
"parallel").

Everything runs under ``interpret=True`` — the CPU PJRT plugin cannot
execute Mosaic custom-calls — so the BlockSpec structure is what we
optimise; wall-clock on CPU is *not* a TPU proxy.

The public entry point :func:`matmul` is a ``jax.custom_vjp`` so that the
L2 model can be differentiated straight through it (Pallas primitives do
not carry automatic transpose rules): the backward pass is two more calls
of the same kernel, dA = dY·Bᵀ and dB = Aᵀ·dY.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default MXU-friendly tile sizes. 128×128 matches the TPU systolic array;
# bk=128 keeps the A/B tiles at 64 KiB each (f32) so a double-buffered
# schedule fits comfortably in the ~16 MiB VMEM budget (see vmem_bytes()).
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128

# Interpret-mode schedule (§Perf L1, EXPERIMENTS.md): pallas interpret=True
# materialises a full-buffer dynamic-update-slice per grid step, so a
# fine 128³ tiling of a [32768,144]@[144,16] im2col matmul costs ~512
# full-output copies (measured 12.4 s vs 9 ms for the same math — 1300×).
# For the CPU artifacts we therefore *coarsen* the tiles so the grid has
# only a handful of steps, capping each block at ~16 MiB. The TPU-shaped
# 128³ schedule remains the documented deployment tiling and is exercised
# by the test suite; set WASGD_TPU_TILES=1 to lower with it.
_FORCE_TPU_TILES = os.environ.get("WASGD_TPU_TILES", "") not in ("", "0")
# Max f32 elements per block under the coarse interpret schedule (16 MiB).
_COARSE_BLOCK_ELEMS = 1 << 22


def vmem_bytes(bm: int = DEFAULT_BM, bn: int = DEFAULT_BN, bk: int = DEFAULT_BK,
               dtype_bytes: int = 4, double_buffered: bool = True) -> int:
    """Estimated VMEM footprint of one grid step of the kernel.

    A-tile (bm×bk) + B-tile (bk×bn) + accumulator (bm×bn); the in/out
    tiles double when the pipeline double-buffers HBM↔VMEM copies. Used by
    DESIGN.md §Perf to pick block shapes: the footprint must stay well
    under 16 MiB for the Mosaic pipeliner to overlap DMA with compute.
    """
    mult = 2 if double_buffered else 1
    a = bm * bk * dtype_bytes * mult
    b = bk * bn * dtype_bytes * mult
    acc = bm * bn * 4  # accumulator is always f32
    return a + b + acc


def _matmul_kernel(a_ref, b_ref, o_ref):
    """One (i, j, k) grid step: o[i,j] += a[i,k] @ b[k,j].

    The output BlockSpec maps every k to the same (i, j) block, so o_ref
    stays in VMEM across the K reduction; we zero it on the first step.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _pick_block(dim: int, pref: int) -> int:
    """Pick a block size ≤ pref that keeps padding waste low.

    For small problem dims (common in the classifier heads: C=10 or 100)
    a full 128 block would be >90% padding; shrink to the padded dim
    itself rounded to the 8-lane sublane granule.
    """
    if dim >= pref:
        return pref
    return max(8, _ceil_to(dim, 8))


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def _matmul_pallas(a: jnp.ndarray, b: jnp.ndarray, bm: int, bn: int, bk: int):
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"contraction mismatch {a.shape} @ {b.shape}"

    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    bk = _pick_block(k, bk)

    mp, np_, kp = _ceil_to(m, bm), _ceil_to(n, bn), _ceil_to(k, bk)
    a_p = jnp.pad(a, ((0, mp - m), (0, kp - k))) if (mp, kp) != (m, k) else a
    b_p = jnp.pad(b, ((0, kp - k), (0, np_ - n))) if (kp, np_) != (k, n) else b

    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(a_p, b_p)
    return out[:m, :n]


def _default_blocks(m: int, k: int, n: int):
    """Block shapes for the default entry points: the MXU 128³ tiling when
    WASGD_TPU_TILES is set, otherwise the coarse interpret schedule."""
    if _FORCE_TPU_TILES:
        return DEFAULT_BM, DEFAULT_BN, DEFAULT_BK
    bk = _ceil_to(k, 8)
    bn = _ceil_to(n, 8)
    per_row = max(bk, bn, 1)
    bm = max(8, min(_ceil_to(m, 8), _COARSE_BLOCK_ELEMS // per_row))
    bm = _ceil_to(bm, 8)
    return bm, bn, bk


@jax.custom_vjp
def matmul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """``a @ b`` through the tiled Pallas kernel, differentiable."""
    bm, bn, bk = _default_blocks(a.shape[0], a.shape[1], b.shape[1])
    return _matmul_pallas(a, b, bm, bn, bk)


def _matmul_fwd(a, b):
    return matmul(a, b), (a, b)


def _matmul_bwd(res, g):
    a, b = res
    # dA = g @ Bᵀ, dB = Aᵀ @ g — same kernel, transposed operands.
    bm, bn, bk = _default_blocks(g.shape[0], g.shape[1], b.shape[0])
    da = _matmul_pallas(g, b.T, bm, bn, bk)
    bm, bn, bk = _default_blocks(a.shape[1], a.shape[0], g.shape[1])
    db = _matmul_pallas(a.T, g, bm, bn, bk)
    return da, db


matmul.defvjp(_matmul_fwd, _matmul_bwd)


def matmul_with_blocks(a, b, bm=DEFAULT_BM, bn=DEFAULT_BN, bk=DEFAULT_BK):
    """Non-differentiable entry exposing block shapes, for the perf sweep."""
    return _matmul_pallas(a, b, bm, bn, bk)
