"""L2: the paper's models (CNN/MLP classifiers) as jitted jax functions.

Everything here is build-time only. Each model *variant* bakes its shapes
(batch size, input dims, class count, layer stack) and is lowered by
:mod:`compile.aot` to three HLO-text artifacts:

- ``train_step(params[D], x, y[B]i32, lr[1]) -> (params'[D], mean_loss, per_ex_loss[B])``
- ``eval_step(params[D], x, y[B]i32)         -> (sum_loss, correct)``
- ``aggregate(stacked[p,D], h[p], a_tilde[1], beta[1]) -> stacked'[p,D]``

The flat-parameter ABI: the rust coordinator only ever sees ``f32[D]``;
this module owns the (static) flatten/unflatten spec. The hot math —
dense GEMMs, the classifier head and the aggregation — routes through the
L1 Pallas kernels, so the lowered HLO contains exactly the schedules
written in ``compile/kernels/``.

Per-example losses come back from ``train_step`` for free (paper §3.3:
the loss energy used for the communication weights is a byproduct of the
forward pass — Eq. 26's estimation windows are then pure bookkeeping on
the rust side).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import aggregate as pallas_aggregate
from .kernels import matmul, softmax_xent


# ---------------------------------------------------------------------------
# Layer stack description
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Conv:
    """3×3 SAME conv + ReLU, optionally followed by 2×2 max-pool."""

    cin: int
    cout: int
    pool: bool = True


@dataclasses.dataclass(frozen=True)
class Dense:
    """Fully-connected layer; ReLU unless it is the logits layer."""

    din: int
    dout: int
    relu: bool = True


Layer = object  # Conv | Dense


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """A fully static description of one model variant."""

    name: str
    input_shape: Tuple[int, ...]  # per-example, e.g. (28, 28, 1) or (784,)
    num_classes: int
    layers: Tuple[Layer, ...]
    batch: int = 32

    @property
    def is_conv(self) -> bool:
        return any(isinstance(l, Conv) for l in self.layers)


def _mlp(name: str, din: int, hidden: Sequence[int], classes: int,
         batch: int = 32) -> ModelSpec:
    dims = [din, *hidden, classes]
    layers = tuple(
        Dense(dims[i], dims[i + 1], relu=(i + 1 < len(dims) - 1))
        for i in range(len(dims) - 1)
    )
    return ModelSpec(name, (din,), classes, layers, batch)


def _cnn(name: str, hw: int, cin: int, convs: Sequence[Tuple[int, bool]],
         hidden: Sequence[int], classes: int, batch: int = 32) -> ModelSpec:
    layers: List[Layer] = []
    c, side = cin, hw
    for cout, pool in convs:
        layers.append(Conv(c, cout, pool))
        c = cout
        if pool:
            side //= 2
    flat = side * side * c
    dims = [flat, *hidden, classes]
    for i in range(len(dims) - 1):
        layers.append(Dense(dims[i], dims[i + 1], relu=(i + 1 < len(dims) - 1)))
    return ModelSpec(name, (hw, hw, cin), classes, tuple(layers), batch)


#: Registry of lowerable variants. `tiny_mlp` exists for fast tests; the
#: paper-scale `cifar_cnn_paper` reproduces the 8-conv/4-dense stack of §5.2.1.
VARIANTS: Dict[str, ModelSpec] = {
    s.name: s
    for s in [
        _mlp("tiny_mlp", 16, [8], 2, batch=8),
        _mlp("mnist_mlp", 784, [256, 128], 10),
        _mlp("fashion_mlp", 784, [256, 128], 10),
        _cnn("tiny_cnn", 8, 1, [(4, True), (8, True)], [], 2, batch=4),
        _cnn("mnist_cnn", 28, 1, [(16, True), (32, True)], [], 10),
        _cnn("cifar_cnn10", 32, 3, [(16, True), (32, True), (64, True)], [128], 10),
        _cnn("cifar_cnn100", 32, 3, [(16, True), (32, True), (64, True)], [128], 100),
        _cnn(
            "cifar_cnn_paper", 32, 3,
            # (3,32)C(64,32)M(64,16)C(128,16)M(128,8)C(256,8)M(256,4)C(512,4)M(512,2)
            [(64, True), (128, True), (256, True), (512, True)],
            [128, 256, 512, 1024],
            10,
            batch=16,
        ),
    ]
}


# ---------------------------------------------------------------------------
# Flat-parameter ABI
# ---------------------------------------------------------------------------


def param_shapes(spec: ModelSpec) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list defining the flat layout."""
    shapes: List[Tuple[str, Tuple[int, ...]]] = []
    for i, layer in enumerate(spec.layers):
        if isinstance(layer, Conv):
            shapes.append((f"conv{i}_w", (3, 3, layer.cin, layer.cout)))
            shapes.append((f"conv{i}_b", (layer.cout,)))
        else:
            shapes.append((f"dense{i}_w", (layer.din, layer.dout)))
            shapes.append((f"dense{i}_b", (layer.dout,)))
    return shapes


def param_count(spec: ModelSpec) -> int:
    return int(sum(np.prod(s) for _, s in param_shapes(spec)))


def unflatten(spec: ModelSpec, flat: jnp.ndarray) -> List[jnp.ndarray]:
    out, off = [], 0
    for _, shape in param_shapes(spec):
        n = int(np.prod(shape))
        out.append(flat[off : off + n].reshape(shape))
        off += n
    return out


def flatten(params: Sequence[jnp.ndarray]) -> jnp.ndarray:
    return jnp.concatenate([p.reshape(-1) for p in params])


def init_params(spec: ModelSpec, seed: int = 0) -> np.ndarray:
    """He-normal init, returned flat as numpy (consumed by rust via file)."""
    rng = np.random.default_rng(seed)
    chunks = []
    for name, shape in param_shapes(spec):
        if name.endswith("_b"):
            chunks.append(np.zeros(shape, np.float32))
        else:
            fan_in = int(np.prod(shape[:-1]))
            std = float(np.sqrt(2.0 / max(fan_in, 1)))
            chunks.append(rng.normal(0.0, std, size=shape).astype(np.float32))
    return np.concatenate([c.reshape(-1) for c in chunks])


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------


#: Conv implementation: "lax" (direct XLA convolution — the CPU-artifact
#: default; on TPU XLA maps convs to the MXU natively) or "pallas"
#: (im2col + the L1 matmul kernel — the explicit MXU mapping, verified by
#: pytest; ~3× slower under interpret mode because every pallas_call
#: round-trips its operands through full-buffer copies — see
#: EXPERIMENTS.md §Perf L2 iteration 2).
import os

CONV_IMPL = os.environ.get("WASGD_CONV_IMPL", "lax")


def _conv3x3_pallas(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """SAME 3×3 conv as im2col + the Pallas matmul (MXU-shaped).

    Patch extraction uses `conv_general_dilated_patches`, whose output
    feature axis orders (cin, kh, kw) — the kernel reshape below matches
    that ordering (verified against `lax.conv_general_dilated` in the
    pytest suite).
    """
    n, h, wd, cin = x.shape
    cout = w.shape[-1]
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(3, 3),
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )  # [N, H, W, cin*9] ordered (cin, kh, kw)
    mat = patches.reshape(n * h * wd, cin * 9)
    # w is [kh, kw, cin, cout] → reorder to (cin, kh, kw, cout)
    wmat = jnp.transpose(w, (2, 0, 1, 3)).reshape(cin * 9, cout)
    out = matmul(mat, wmat).reshape(n, h, wd, cout)
    return out + b


def _conv3x3_lax(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """SAME 3×3 conv through `lax.conv_general_dilated` (XLA native)."""
    return (
        jax.lax.conv_general_dilated(
            x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
        )
        + b
    )


def _conv3x3(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    if CONV_IMPL == "pallas":
        return _conv3x3_pallas(x, w, b)
    return _conv3x3_lax(x, w, b)


def _maxpool2(x: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def forward(spec: ModelSpec, flat: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Logits [B, C] from flat params and a batch of inputs."""
    params = unflatten(spec, flat)
    b = x.shape[0]
    if spec.is_conv:
        h = x.reshape((b, *spec.input_shape))
    else:
        h = x.reshape((b, spec.input_shape[0]))
    pi = 0
    for layer in spec.layers:
        if isinstance(layer, Conv):
            w, bias = params[pi], params[pi + 1]
            pi += 2
            h = jax.nn.relu(_conv3x3(h, w, bias))
            if layer.pool:
                h = _maxpool2(h)
        else:
            if h.ndim > 2:
                h = h.reshape(b, -1)
            w, bias = params[pi], params[pi + 1]
            pi += 2
            h = matmul(h, w) + bias
            if layer.relu:
                h = jax.nn.relu(h)
    return h


# ---------------------------------------------------------------------------
# The three lowerable entry points
# ---------------------------------------------------------------------------


def make_train_step(spec: ModelSpec) -> Callable:
    """SGD step. Per-example losses are returned so the coordinator can
    maintain the paper's free loss-estimation windows (Eq. 26)."""

    def loss_fn(flat, x, onehot):
        logits = forward(spec, flat, x)
        per_ex = softmax_xent(logits, onehot)
        return jnp.mean(per_ex), per_ex

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(flat, x, y, lr):
        onehot = jax.nn.one_hot(y, spec.num_classes, dtype=jnp.float32)
        (mean_loss, per_ex), g = grad_fn(flat, x, onehot)
        new_flat = flat - lr[0] * g
        return new_flat, mean_loss, per_ex

    return train_step


def make_eval_step(spec: ModelSpec) -> Callable:
    def eval_step(flat, x, y):
        logits = forward(spec, flat, x)
        onehot = jax.nn.one_hot(y, spec.num_classes, dtype=jnp.float32)
        per_ex = softmax_xent(logits, onehot)
        pred = jnp.argmax(logits, axis=-1)
        correct = jnp.sum((pred == y).astype(jnp.float32))
        return jnp.sum(per_ex), correct

    return eval_step


def make_aggregate(p: int) -> Callable:
    """The communication step for a cohort of p workers (Eq. 10+13)."""

    def agg(stacked, h, a_tilde, beta):
        return pallas_aggregate(stacked, h, a_tilde[0], beta[0])

    return agg


def example_args(spec: ModelSpec):
    """ShapeDtypeStructs for lowering train/eval."""
    d = param_count(spec)
    xdim = int(np.prod(spec.input_shape))
    flat = jax.ShapeDtypeStruct((d,), jnp.float32)
    x = jax.ShapeDtypeStruct((spec.batch, xdim), jnp.float32)
    y = jax.ShapeDtypeStruct((spec.batch,), jnp.int32)
    lr = jax.ShapeDtypeStruct((1,), jnp.float32)
    return flat, x, y, lr
