"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes/dtypes; assert_allclose against ref.py is the
core correctness signal for the kernels that end up inside the AOT HLO.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    aggregate,
    aggregate_with_blocks,
    boltzmann_weights,
    matmul,
    matmul_with_blocks,
    softmax_xent,
    softmax_xent_with_grad,
)
from compile.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)


def _rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(jax.random.PRNGKey(key), shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    m=st.integers(1, 200),
    k=st.integers(1, 200),
    n=st.integers(1, 200),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    a = _rand(seed, (m, k))
    b = _rand(seed + 1, (k, n))
    got = matmul(a, b)
    want = ref.matmul_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(
    m=st.integers(1, 64),
    k=st.integers(1, 64),
    n=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_vjp_matches_ref(m, k, n, seed):
    a = _rand(seed, (m, k))
    b = _rand(seed + 1, (k, n))
    f = lambda a, b: jnp.sum(matmul(a, b) ** 2)
    g = lambda a, b: jnp.sum(jnp.matmul(a, b) ** 2)
    da1, db1 = jax.grad(f, argnums=(0, 1))(a, b)
    da2, db2 = jax.grad(g, argnums=(0, 1))(a, b)
    np.testing.assert_allclose(da1, da2, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(db1, db2, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("bm,bn,bk", [(8, 8, 8), (32, 16, 64), (128, 128, 128)])
def test_matmul_block_shapes_equivalent(bm, bn, bk):
    """Block shape is a schedule choice, never a numerics choice."""
    a = _rand(7, (100, 70))
    b = _rand(8, (70, 30))
    want = ref.matmul_ref(a, b)
    got = matmul_with_blocks(a, b, bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_matmul_bf16_inputs():
    a = _rand(1, (33, 17), dtype=jnp.bfloat16)
    b = _rand(2, (17, 9), dtype=jnp.bfloat16)
    got = matmul(a, b)
    want = ref.matmul_ref(a, b)
    assert got.dtype == jnp.float32  # f32 accumulation
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


def test_matmul_identity():
    a = _rand(3, (50, 50))
    np.testing.assert_allclose(matmul(a, jnp.eye(50)), a, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# softmax cross-entropy
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    b=st.integers(1, 150),
    c=st.sampled_from([2, 10, 100]),
    scale=st.sampled_from([0.1, 1.0, 10.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_xent_matches_ref(b, c, scale, seed):
    logits = _rand(seed, (b, c), scale=scale)
    y = jax.random.randint(jax.random.PRNGKey(seed + 1), (b,), 0, c)
    onehot = jax.nn.one_hot(y, c)
    l1, d1 = softmax_xent_with_grad(logits, onehot)
    l2, d2 = ref.softmax_xent_ref(logits, onehot)
    np.testing.assert_allclose(l1, l2, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(d1, d2, rtol=1e-4, atol=1e-5)


def test_xent_vjp_is_dlogits():
    logits = _rand(5, (17, 10), scale=3.0)
    y = jax.random.randint(jax.random.PRNGKey(6), (17,), 0, 10)
    onehot = jax.nn.one_hot(y, 10)
    g = jax.grad(lambda lg: jnp.sum(softmax_xent(lg, onehot)))(logits)
    _, want = ref.softmax_xent_ref(logits, onehot)
    np.testing.assert_allclose(g, want, rtol=1e-4, atol=1e-5)


def test_xent_extreme_logits_stable():
    """Max-subtraction must keep huge logits finite."""
    logits = jnp.array([[1e4, -1e4, 0.0], [500.0, 499.0, -500.0]], jnp.float32)
    onehot = jax.nn.one_hot(jnp.array([0, 1]), 3)
    loss, dlg = softmax_xent_with_grad(logits, onehot)
    assert bool(jnp.all(jnp.isfinite(loss)))
    assert bool(jnp.all(jnp.isfinite(dlg)))
    # Correct-and-confident row 0 → ~0 loss.
    assert float(loss[0]) < 1e-3


def test_xent_uniform_logits():
    b, c = 9, 10
    logits = jnp.zeros((b, c))
    onehot = jax.nn.one_hot(jnp.arange(b) % c, c)
    loss, _ = softmax_xent_with_grad(logits, onehot)
    np.testing.assert_allclose(loss, np.full(b, np.log(c)), rtol=1e-5)


# ---------------------------------------------------------------------------
# weighted aggregation (the paper's Eq. 10+13)
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    p=st.sampled_from([2, 3, 4, 8, 16]),
    d=st.integers(1, 3000),
    a_tilde=st.sampled_from([0.0, 0.1, 1.0, 10.0]),
    beta=st.sampled_from([0.0, 0.3, 0.7, 1.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_aggregate_matches_ref(p, d, a_tilde, beta, seed):
    x = _rand(seed, (p, d))
    h = jnp.abs(_rand(seed + 1, (p,))) + 0.05
    got = aggregate(x, h, a_tilde, beta)
    want = ref.aggregate_ref(x, h, a_tilde, beta)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@settings(**SETTINGS)
@given(
    p=st.sampled_from([2, 4, 8]),
    a_tilde=st.sampled_from([0.0, 0.5, 2.0, 50.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_boltzmann_weights_simplex(p, a_tilde, seed):
    """θ is always a probability vector (Σθ=1, θ≥0)."""
    h = jnp.abs(_rand(seed, (p,))) + 1e-3
    th = boltzmann_weights(h, a_tilde)
    np.testing.assert_allclose(float(jnp.sum(th)), 1.0, rtol=1e-5)
    assert bool(jnp.all(th >= 0))


def test_boltzmann_property1_equal_limit():
    """Paper Property 1: ã→0 ⇒ θ = 1/p exactly."""
    h = jnp.array([0.1, 5.0, 2.0, 0.7])
    th = boltzmann_weights(h, 0.0)
    np.testing.assert_allclose(th, np.full(4, 0.25), rtol=1e-6)


def test_boltzmann_property1_argmin_limit():
    """Paper Property 1: ã→∞ ⇒ best (lowest-loss) worker dominates."""
    h = jnp.array([0.1, 5.0, 2.0, 0.7])
    th = np.asarray(boltzmann_weights(h, 1e4))
    assert th.argmax() == 0
    assert th[0] > 0.999


def test_boltzmann_monotone_in_loss():
    """Lower loss energy ⇒ weakly larger weight, any temperature."""
    h = jnp.array([0.5, 1.0, 2.0, 4.0])
    for a in [0.1, 1.0, 10.0]:
        th = np.asarray(boltzmann_weights(h, a))
        assert all(th[i] >= th[i + 1] - 1e-7 for i in range(3))


def test_aggregate_beta0_identity():
    x = _rand(11, (4, 257))
    h = jnp.ones(4)
    got = aggregate(x, h, 1.0, 0.0)
    np.testing.assert_allclose(got, x, rtol=1e-5, atol=1e-6)


def test_aggregate_beta1_consensus():
    """β=1 ⇒ every worker holds the identical aggregate (paper §4.1)."""
    x = _rand(12, (4, 257))
    h = jnp.abs(_rand(13, (4,))) + 0.1
    got = np.asarray(aggregate(x, h, 1.0, 1.0))
    for i in range(1, 4):
        np.testing.assert_allclose(got[i], got[0], rtol=1e-5, atol=1e-6)


def test_aggregate_preserves_consensus_fixedpoint():
    """If all workers agree already, aggregation is a no-op for any β, ã."""
    row = _rand(14, (1, 129))
    x = jnp.tile(row, (8, 1))
    h = jnp.abs(_rand(15, (8,))) + 0.1
    got = aggregate(x, h, 3.0, 0.6)
    np.testing.assert_allclose(got, x, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bd", [8, 64, 1024, 8192])
def test_aggregate_panel_width_equivalent(bd):
    x = _rand(16, (4, 1234))
    h = jnp.abs(_rand(17, (4,))) + 0.1
    want = ref.aggregate_ref(x, h, 1.0, 0.8)
    got = aggregate_with_blocks(x, h, 1.0, 0.8, bd=bd)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_aggregate_scale_free_energies():
    """h is normalised by Σh (Eq. 12-13): scaling all energies is a no-op."""
    x = _rand(18, (4, 100))
    h = jnp.abs(_rand(19, (4,))) + 0.1
    a = aggregate(x, h, 2.0, 0.9)
    b = aggregate(x, h * 1000.0, 2.0, 0.9)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)
