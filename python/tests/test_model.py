"""L2 correctness: model shapes, flat-parameter ABI, training dynamics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(42)


def _batch(rng, spec, scale=1.0):
    xdim = int(np.prod(spec.input_shape))
    x = jnp.asarray(rng.normal(size=(spec.batch, xdim)).astype(np.float32) * scale)
    y = jnp.asarray(rng.integers(0, spec.num_classes, size=(spec.batch,)).astype(np.int32))
    return x, y


# ---------------------------------------------------------------------------
# Flat ABI
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", list(M.VARIANTS))
def test_param_count_matches_layout(name):
    spec = M.VARIANTS[name]
    total = sum(int(np.prod(s)) for _, s in M.param_shapes(spec))
    assert total == M.param_count(spec)


@pytest.mark.parametrize("name", ["tiny_mlp", "mnist_mlp", "mnist_cnn"])
def test_flatten_unflatten_roundtrip(name):
    spec = M.VARIANTS[name]
    flat = jnp.asarray(M.init_params(spec, 3))
    parts = M.unflatten(spec, flat)
    back = M.flatten(parts)
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(back))


def test_init_params_deterministic():
    spec = M.VARIANTS["tiny_mlp"]
    a = M.init_params(spec, 7)
    b = M.init_params(spec, 7)
    c = M.init_params(spec, 8)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)


def test_init_biases_zero():
    spec = M.VARIANTS["tiny_mlp"]
    flat = M.init_params(spec, 0)
    parts = M.unflatten(spec, jnp.asarray(flat))
    names = [n for n, _ in M.param_shapes(spec)]
    for n, p in zip(names, parts):
        if n.endswith("_b"):
            assert float(jnp.abs(p).max()) == 0.0


# ---------------------------------------------------------------------------
# Forward / conv correctness
# ---------------------------------------------------------------------------


def test_conv3x3_pallas_matches_lax_conv(rng):
    """The explicit im2col+Pallas MXU mapping must equal XLA's native conv
    (whichever of the two the artifacts were lowered with)."""
    x = jnp.asarray(rng.normal(size=(2, 16, 16, 3)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(3, 3, 3, 5)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(5,)).astype(np.float32))
    got = M._conv3x3_pallas(x, w, b)
    want = M._conv3x3_lax(x, w, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_conv3x3_dispatch_is_consistent(rng):
    x = jnp.asarray(rng.normal(size=(1, 8, 8, 2)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(3, 3, 2, 4)).astype(np.float32))
    b = jnp.zeros(4, jnp.float32)
    got = M._conv3x3(x, w, b)
    want = (M._conv3x3_pallas if M.CONV_IMPL == "pallas" else M._conv3x3_lax)(x, w, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("name", ["tiny_mlp", "mnist_mlp", "mnist_cnn", "cifar_cnn10"])
def test_forward_logit_shape(name, rng):
    spec = M.VARIANTS[name]
    flat = jnp.asarray(M.init_params(spec, 0))
    x, _ = _batch(rng, spec)
    logits = M.forward(spec, flat, x)
    assert logits.shape == (spec.batch, spec.num_classes)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_maxpool_halves_spatial(rng):
    x = jnp.asarray(rng.normal(size=(1, 8, 8, 2)).astype(np.float32))
    out = M._maxpool2(x)
    assert out.shape == (1, 4, 4, 2)
    assert float(out[0, 0, 0, 0]) == float(jnp.max(x[0, :2, :2, 0]))


# ---------------------------------------------------------------------------
# Training dynamics
# ---------------------------------------------------------------------------


def test_train_step_decreases_loss_tiny(rng):
    spec = M.VARIANTS["tiny_mlp"]
    flat = jnp.asarray(M.init_params(spec, 0))
    ts = jax.jit(M.make_train_step(spec))
    x, y = _batch(rng, spec)
    lr = jnp.asarray([0.1], jnp.float32)
    first = None
    for _ in range(40):
        flat, ml, per_ex = ts(flat, x, y, lr)
        if first is None:
            first = float(ml)
    assert float(ml) < first * 0.7


def test_train_step_per_example_loss_consistent(rng):
    """mean_loss output must equal the mean of the per-example vector —
    the coordinator's free loss-estimation (Eq. 26) relies on it."""
    spec = M.VARIANTS["tiny_mlp"]
    flat = jnp.asarray(M.init_params(spec, 1))
    ts = jax.jit(M.make_train_step(spec))
    x, y = _batch(rng, spec)
    _, ml, per_ex = ts(flat, x, y, jnp.asarray([0.05], jnp.float32))
    np.testing.assert_allclose(float(ml), float(jnp.mean(per_ex)), rtol=1e-5)


def test_train_step_lr_zero_is_identity(rng):
    spec = M.VARIANTS["tiny_mlp"]
    flat = jnp.asarray(M.init_params(spec, 2))
    ts = jax.jit(M.make_train_step(spec))
    x, y = _batch(rng, spec)
    new, _, _ = ts(flat, x, y, jnp.asarray([0.0], jnp.float32))
    np.testing.assert_allclose(np.asarray(new), np.asarray(flat), atol=1e-7)


def test_eval_step_counts(rng):
    spec = M.VARIANTS["tiny_mlp"]
    flat = jnp.asarray(M.init_params(spec, 0))
    es = jax.jit(M.make_eval_step(spec))
    x, y = _batch(rng, spec)
    sl, correct = es(flat, x, y)
    assert 0.0 <= float(correct) <= spec.batch
    assert float(sl) > 0.0


def test_gradient_matches_finite_difference(rng):
    """Spot-check the full pallas-backed backward pass numerically."""
    spec = M.VARIANTS["tiny_mlp"]
    flat = jnp.asarray(M.init_params(spec, 5))
    x, y = _batch(rng, spec)
    onehot = jax.nn.one_hot(y, spec.num_classes)

    def loss(f):
        logits = M.forward(spec, f, x)
        m = jnp.max(logits, axis=-1, keepdims=True)
        z = logits - m
        lse = jnp.log(jnp.sum(jnp.exp(z), axis=-1, keepdims=True))
        return jnp.mean(-jnp.sum(onehot * (z - lse), axis=-1))

    g = jax.grad(loss)(flat)
    eps = 1e-3
    for idx in [0, 17, int(M.param_count(spec)) - 1]:
        e = jnp.zeros_like(flat).at[idx].set(eps)
        fd = (float(loss(flat + e)) - float(loss(flat - e))) / (2 * eps)
        assert abs(fd - float(g[idx])) < 5e-3, f"idx {idx}: fd={fd} ad={float(g[idx])}"


# ---------------------------------------------------------------------------
# Aggregate entry used by AOT
# ---------------------------------------------------------------------------


def test_make_aggregate_shapes():
    agg = jax.jit(M.make_aggregate(4))
    x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 123)).astype(np.float32))
    h = jnp.abs(x[:, 0]) + 0.1
    out = agg(x, h, jnp.asarray([1.0], jnp.float32), jnp.asarray([0.8], jnp.float32))
    assert out.shape == (4, 123)
